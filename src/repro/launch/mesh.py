"""Production mesh definitions (defined as functions — importing this module
never touches jax device state)."""
from __future__ import annotations

import jax

# TPU v5e hardware constants used by the roofline (§Roofline).
PEAK_BF16_FLOPS = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link (~per direction)
HBM_BYTES = 16 * 1024 ** 3        # 16 GiB per chip


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_for(n_devices: int, model_parallel: int = 16):
    """Elastic variant: best (data, model) mesh for an arbitrary device
    count (used by the elastic re-mesh path)."""
    tp = min(model_parallel, n_devices)
    while n_devices % tp:
        tp //= 2
    return jax.make_mesh((n_devices // tp, tp), ("data", "model"))
