"""``repro.api`` — the GrJAX polyglot frontend, in one import.

    import repro.api as gr

    with gr.runtime(policy="parallel"):
        x = gr.array(host_data, name="x")
        sq = gr.function(square_kernel, modes=("const", "out"),
                         outputs=0, name="square")
        y = sq(x)                 # runtime allocates y, infers the DAG

This is the single stable call surface the serving engine, the trainer,
graph capture and the benchsuite all speak; later frontends (autotuning,
tracing, other host languages) target it rather than the scheduler
internals.  The annotation helpers (``const``/``out``/``inout``) and the
scheduler factory are re-exported for code that still builds argument lists
explicitly or constructs runtimes by hand.
"""
from .core.frontend import (GrFunction, NoActiveRuntimeError, array,
                            current_runtime, function, get_runtime, runtime,
                            set_runtime)
from .core import (AccessMode, Arg, BackingTier, CompressedHostTier,
                   DiskTier, GrScheduler, ManagedArray, PeerDeviceTier,
                   const, inout, make_scheduler, out)

__all__ = [
    "GrFunction", "NoActiveRuntimeError", "array", "current_runtime",
    "function", "get_runtime", "runtime", "set_runtime",
    "AccessMode", "Arg", "GrScheduler", "ManagedArray", "const", "inout",
    "make_scheduler", "out",
    "BackingTier", "CompressedHostTier", "DiskTier", "PeerDeviceTier",
]
