from .steps import TrainState, make_decode_step, make_loss_fn, \
    make_prefill_step, make_train_step
from .trainer import SimulatedFailure, TaskGraphTrainer, TrainerReport

__all__ = ["TrainState", "make_train_step", "make_prefill_step",
           "make_decode_step", "make_loss_fn", "TaskGraphTrainer",
           "TrainerReport", "SimulatedFailure"]
