"""Submesh space-sharing: the paper's SM-level space-sharing re-expressed at
pod level (DESIGN.md §2).

TPU cores run one program at a time, so *within-chip* space-sharing does not
transfer; the transferable insight is that **independent tasks should occupy
idle resources**.  `SubmeshPool` splits a device mesh into disjoint
submeshes ("lanes" of whole devices) and the GrJAX stream manager schedules
independent device tasks (ensemble members, eval-during-train, per-request
serving) onto them concurrently — JAX dispatches asynchronously per device,
so disjoint submeshes genuinely execute in parallel.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Callable, Dict, List, Optional

import numpy as np

from ..core import GrScheduler
from ..core.frontend import GrFunction
from ..core.managed import ManagedValue

if TYPE_CHECKING:  # pragma: no cover
    from jax.sharding import Mesh

# NOTE: jax / jax.sharding are imported lazily inside the functions that need
# them (matching executor.py's in-function imports) so this module can be
# imported — e.g. during offline test collection — on hosts without jax.


class SubmeshPool:
    """Disjoint submeshes acting as device-level lanes."""

    def __init__(self, devices=None, n_lanes: int = 2,
                 axis_names=("data", "model")) -> None:
        import jax
        from jax.sharding import Mesh

        devices = list(devices if devices is not None else jax.devices())
        assert len(devices) % n_lanes == 0, "devices must split evenly"
        per = len(devices) // n_lanes
        self.meshes: List["Mesh"] = []
        for i in range(n_lanes):
            devs = np.asarray(devices[i * per:(i + 1) * per])
            self.meshes.append(Mesh(devs.reshape(-1, 1), axis_names))

    def __len__(self) -> int:
        return len(self.meshes)

    def mesh(self, lane: int) -> "Mesh":
        return self.meshes[lane % len(self.meshes)]


class SpaceSharedRunner:
    """Run independent jitted tasks space-shared across a SubmeshPool, with
    dependencies still inferred by the GrJAX scheduler."""

    def __init__(self, pool: SubmeshPool,
                 scheduler: Optional[GrScheduler] = None) -> None:
        self.pool = pool
        self.sched = scheduler or GrScheduler(policy="parallel",
                                              max_lanes=len(pool))
        # Declared identity per (name, arity): the per-submit closure below
        # must be re-created (it binds this submit's fn and element), but
        # re-minting a fresh GrFunction identity each time would make every
        # captured episode re-record — replay matches on fn_key and always
        # executes the *current* call's closure, so sharing the fid is safe.
        self._task_ids: Dict[tuple, int] = {}

    def submit(self, fn: Callable, value_args: List, name: str = "task"):
        """fn(*device_values) -> result; runs on the lane's submesh."""
        result = ManagedValue(self.sched, None, name=f"{name}_out")

        def kernel(*vals):
            _out_placeholder = vals[-1]
            ins = vals[:-1]
            # the lane id chosen by the stream manager selects the submesh
            lane = kernel_elem.stream or 0
            mesh = self.pool.mesh(lane)
            with mesh:
                return fn(*ins)

        key = (name, len(value_args))
        task = GrFunction(kernel,
                          modes=("const",) * len(value_args) + ("out",),
                          name=name, scheduler=self.sched,
                          _fid=self._task_ids.get(key))
        self._task_ids.setdefault(key, task.fid)
        kernel_elem = task(*value_args, result)
        return result

    def gather(self, results):
        return [r.get() for r in results]
