"""Jittable train / prefill / decode steps used by the launcher, the
dry-run, and the examples.

``make_train_step`` builds the full production step: gradient-accumulation
scan over microbatches (bounds activation memory for the 340B config),
per-layer remat, AdamW (optionally 8-bit state), MoE aux losses.  Donated
buffers and shardings are applied by the caller (launch/dryrun.py).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..models import (cross_entropy_loss, forward_decode, forward_prefill,
                      forward_train)
from ..models.config import ArchConfig
from ..optim import AdamW, AdamWState
from ..sharding.context import constrain_like_params


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def make_loss_fn(cfg: ArchConfig, use_flash: bool = False,
                 remat: bool = True, seq_shard: bool = False):
    def loss_fn(params, micro_batch):
        logits, aux = forward_train(cfg, params, micro_batch,
                                    use_flash=use_flash, remat=remat,
                                    seq_shard=seq_shard)
        loss = cross_entropy_loss(logits, micro_batch["labels"])
        total = loss + aux["moe_aux"] + aux["moe_z"]
        return total, {"ce_loss": loss, **aux}
    return loss_fn


def make_train_step(cfg: ArchConfig, optimizer: AdamW, *,
                    use_flash: bool = False, remat: bool = True,
                    seq_shard: bool = False):
    """Returns train_step(state, batch) -> (state, metrics).

    ``batch`` leaves are shaped (accum, micro_batch, ...): the step scans
    over the leading accumulation axis, accumulating f32 gradients, then
    applies one optimizer update.
    """
    loss_fn = make_loss_fn(cfg, use_flash, remat, seq_shard)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        accum = jax.tree_util.tree_leaves(batch)[0].shape[0]

        def micro(carry, mb):
            gacc, lacc = carry
            (_, metrics), grads = grad_fn(state.params, mb)
            # keep per-micro grads + the accumulator in FSDP storage
            # sharding: DP sync becomes a reduce-scatter, not an all-reduce
            grads = constrain_like_params(grads)
            gacc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32), gacc, grads)
            gacc = constrain_like_params(gacc)
            return (gacc, lacc + metrics["ce_loss"]), None

        zeros = constrain_like_params(jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state.params))
        (grads, loss_sum), _ = jax.lax.scan(micro, (zeros, jnp.float32(0)),
                                            batch)
        grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
        params, opt, metrics = optimizer.update(grads, state.opt, state.params)
        metrics["loss"] = loss_sum / accum
        return TrainState(params, opt), metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, use_flash: bool = False):
    def prefill_step(params, batch, cache):
        return forward_prefill(cfg, params, batch, cache, use_flash=use_flash)
    return prefill_step


def make_decode_step(cfg: ArchConfig):
    def serve_step(params, tokens, cache, pos):
        """One new token for the whole batch against the KV/state cache."""
        logits, cache = forward_decode(cfg, params, tokens, cache, pos)
        next_tokens = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return next_tokens, logits, cache
    return serve_step
