"""TaskGraphTrainer — the paper's runtime scheduler as a first-class
feature of the training loop (DESIGN.md §3).

Every training step is issued as plain sequential host code; the GrJAX
scheduler infers the dependency structure and overlaps:

* ``load_batch``  (host)      — synthetic pipeline / disk reads;
* ``h2d``         (transfer)  — auto-prefetch of the next batch, overlapped
                                 with the current step's compute (the
                                 paper's CT/TC overlap at step granularity);
* ``train_step``  (kernel)    — the jitted device step (RAW on state, WAR on
                                 the double-buffered batch slots);
* ``metrics``     (host read) — syncs only the lane owning the metrics;
* ``checkpoint``  (host)      — async snapshot off the critical path.

Fault tolerance: checkpoint/restart (exact resume via the deterministic
data stream), failure injection, straggler detection via the scheduler's
kernel history (§IV-A put to work).
"""
from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax

from ..checkpoint import CheckpointManager
from ..core import GrScheduler
from ..core.frontend import function
from ..core.managed import ManagedValue
from ..data import SyntheticTokenStream
from ..models.config import ArchConfig
from ..optim import AdamW
from .steps import TrainState, make_train_step


class SimulatedFailure(RuntimeError):
    pass


@dataclass
class TrainerReport:
    steps_run: int = 0
    losses: List[float] = field(default_factory=list)
    restarts: int = 0
    stragglers: int = 0
    wall_time_s: float = 0.0


class TaskGraphTrainer:
    def __init__(self, cfg: ArchConfig, *, seq_len: int = 128,
                 global_batch: int = 8, accum: int = 1,
                 optimizer: Optional[AdamW] = None,
                 scheduler: Optional[GrScheduler] = None,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 10,
                 use_flash: bool = False, remat: bool = True,
                 seed: int = 0,
                 straggler_factor: float = 3.0,
                 capture_steps: bool = True) -> None:
        self.cfg = cfg
        self.optimizer = optimizer or AdamW(lr=1e-3, warmup=10,
                                            total_steps=1000)
        self.sched = scheduler or GrScheduler(policy="parallel")
        self.stream = SyntheticTokenStream(cfg, seq_len, global_batch,
                                           accum=accum, seed=seed)
        self.train_step = jax.jit(make_train_step(cfg, self.optimizer,
                                                  use_flash=use_flash,
                                                  remat=remat),
                                  donate_argnums=(0,))
        self.ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        self.sched.executor.history.straggler_factor = straggler_factor
        # The steady-state step issues an identical episode every iteration;
        # capture/replay turns its per-launch scheduling into a plan launch.
        self._capture_steps = capture_steps
        self._seq = seq_len

    # ------------------------------------------------------------------
    def init_state(self, key=None) -> TrainState:
        from ..models import init_lm
        key = key if key is not None else jax.random.PRNGKey(0)
        params = init_lm(key, self.cfg)
        return TrainState(params, self.optimizer.init(params))

    # ------------------------------------------------------------------
    def run(self, n_steps: int, state: Optional[TrainState] = None,
            fail_at: Optional[int] = None, resume: bool = True,
            metrics_every: int = 5) -> TrainerReport:
        """Run the training loop through the GrJAX scheduler.  ``fail_at``
        injects a node failure at that step (for the restart test); with a
        checkpoint dir + ``resume=True``, training resumes from the latest
        checkpoint and continues to ``n_steps``."""
        report = TrainerReport()
        t0 = time.perf_counter()
        start_step = 0
        if state is None:
            state = self.init_state()
            if self.ckpt and resume:
                # restore_latest resolves (step, state) atomically — resuming
                # the loop from a step that disagrees with the restored state
                # is what broke bit-exact restart.
                ck_step, ck_state, extra = self.ckpt.restore_latest(like=state)
                if ck_step is not None:
                    saved_seed = extra.get("stream_seed")
                    if saved_seed is not None and saved_seed != self.stream.seed:
                        raise ValueError(
                            f"checkpoint was trained with stream seed "
                            f"{saved_seed}, trainer has {self.stream.seed}: "
                            f"resume would not be exact")
                    start_step, state = ck_step, ck_state
                    report.restarts += 1

        sched = self.sched
        state_v = ManagedValue(sched, state, name="train_state")
        metrics_v = ManagedValue(sched, None, name="metrics")
        # double-buffered host batch slots (WAR handled by the scheduler)
        slots = [
            {k: sched.array(v, name=f"{k}_{i}")
             for k, v in self.stream.batch(0).items()}
            for i in range(2)
        ]

        def step_kernel(state, *flat_batch):
            names = sorted(slots[0].keys())
            batch = dict(zip(names, flat_batch))
            new_state, metrics = self.train_step(state, batch)
            return new_state, metrics

        # Declared once per run: inout train state, const batch slots, out
        # metrics.  The declaration is what capture keys plans by, so every
        # steady-state step replays the same plan.
        slot_keys = sorted(slots[0].keys())
        step_fn = function(
            step_kernel,
            modes=("inout",) + ("const",) * len(slot_keys) + ("out",),
            name="train_step", scheduler=sched)

        for step in range(start_step, n_steps):
            if fail_at is not None and step == fail_at:
                raise SimulatedFailure(f"injected node failure at step {step}")
            slot = slots[step % 2]
            host_batch = self.stream.batch(step)        # host element
            for k in slot_keys:
                slot[k].write(host_batch[k])            # WAR vs step-2 kernel
            # Auto-capture the steady-state step: the double-buffered slots
            # alternate arrays but bind the same plan slots, so one plan
            # covers both phases after a short warm-up.
            ctx = (sched.capture("train_step") if self._capture_steps
                   else contextlib.nullcontext())
            with ctx:
                step_fn(state_v, *(slot[k] for k in slot_keys), metrics_v)
            if (step + 1) % metrics_every == 0 or step == n_steps - 1:
                m = metrics_v.get()                     # syncs this lane only
                report.losses.append(float(m["loss"]))
            if self.ckpt and (step + 1) % self.ckpt_every == 0:
                snap = state_v.get()
                self.ckpt.save(step + 1, snap,
                               extra={"stream_seed": self.stream.seed})
            report.steps_run += 1

        sched.sync()
        if self.ckpt:
            self.ckpt.wait()
        report.stragglers = self.sched.executor.history.stragglers_seen
        report.wall_time_s = time.perf_counter() - t0
        return report

    def run_with_restart(self, n_steps: int, fail_at: int) -> TrainerReport:
        """Convenience: run, crash at ``fail_at``, restart from the latest
        checkpoint, finish — the full fault-tolerance cycle."""
        assert self.ckpt is not None, "needs a checkpoint dir"
        try:
            self.run(n_steps, fail_at=fail_at)
        except SimulatedFailure:
            pass
        # new scheduler (the "restarted job")
        self.sched = GrScheduler(policy=self.sched.policy)
        report = self.run(n_steps)
        report.restarts += 1
        return report
