"""Batched serving engine on top of the GrJAX scheduler.

Requests are queued, grouped into fixed-shape batches (same prompt length →
one compiled prefill/decode pair, no retracing), and each batch's
prefill+decode pipeline is issued as a *computational element*: independent
batches land on separate scheduler lanes and overlap (the paper's
space-sharing applied to inference), while the shared read-only weights are
tracked as a const dependency — exactly the two-branch pattern of Fig. 2.

Multi-tenant QoS: ``submit(..., tenant=, priority=, deadline_s=)`` tags each
request.  Batches are assembled per (shape, tenant, priority) and issued in
**weighted-fair** order (stride scheduling — each tenant's virtual time
advances by 1/weight per batch), and the underlying launches carry the tags
so the scheduler's priority-weighted space-sharing and per-tenant stats see
them.  Deadline'd requests add **EDF batch assembly**: each tenant's ready
batches order earliest-deadline-first, and while any tenant's head batch
carries a deadline the earliest one issues ahead of the stride order (the
stride clock still charges it, so fairness debt is preserved).  A
``max_batch_wait_s`` bound holds under-full batches back for late arrivals
instead of issuing fragments, flushing them once the oldest member ages out
(or its deadline draws near).  ``submit`` and ``flush`` are thread-safe via
the scheduler's submission pipeline lock.

Per-slot ragged positions (token-level continuous batching) would need a
vector-``pos`` decode mask; noted as future work in DESIGN.md.
"""
from __future__ import annotations

import collections
import contextlib
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from ..core import (DEFAULT_TENANT, GrScheduler, make_scheduler,
                    priority_weight)
from ..core.frontend import GrFunction, function
from ..core.managed import ManagedValue
from ..models import init_cache
from ..models.config import ArchConfig
from .steps import make_decode_step, make_prefill_step


@dataclass
class Request:
    rid: int
    tokens: np.ndarray            # (prompt_len,)
    new_tokens: int
    tenant: str = DEFAULT_TENANT
    priority: int = 0
    deadline_s: Optional[float] = None   # per-request latency SLO (relative)
    t_submit: float = 0.0                # host clock at submit()
    result: Optional[np.ndarray] = None

    @property
    def deadline_t(self) -> float:
        """Absolute deadline (+inf when the request has none)."""
        if self.deadline_s is None:
            return float("inf")
        return self.t_submit + self.deadline_s


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params, *, batch_size: int = 2,
                 max_new_tokens: int = 16,
                 scheduler: Optional[GrScheduler] = None,
                 capture: bool = True,
                 max_batch_wait_s: Optional[float] = None) -> None:
        self.cfg = cfg
        self.batch = batch_size
        self.max_new = max_new_tokens
        # Age bound for under-full batches: flush() holds a partial batch
        # back (for late same-shape arrivals) until its oldest member has
        # waited this long.  None = issue partials immediately (legacy).
        self.max_batch_wait_s = max_batch_wait_s
        self._owns_sched = scheduler is None
        self.sched = scheduler or make_scheduler("parallel")
        # Steady-state batches of one shape repeat the identical episode;
        # capture/replay amortizes DAG inference + lane assignment across
        # them (one plan per (prompt_len, new_tokens) signature).
        self.capture = capture and self.sched.policy == "parallel"
        self.params_v = ManagedValue(self.sched, params, name="weights")
        self._queue: "collections.deque[Request]" = collections.deque()
        self._rid = 0
        self._prefill = jax.jit(make_prefill_step(cfg))
        self._decode = jax.jit(make_decode_step(cfg))
        self._pending: List[tuple] = []
        # Declared once per (prompt_len, new_tokens) shape and reused for
        # every batch of that shape: capture plans key on the declared
        # function's identity, so a stable GrFunction per shape is what lets
        # steady-state batches replay one plan instead of re-recording.
        self._fns: Dict[tuple, GrFunction] = {}

    # ------------------------------------------------------------------
    def submit(self, tokens: np.ndarray, new_tokens: int = 0, *,
               tenant: str = DEFAULT_TENANT, priority: int = 0,
               deadline_s: Optional[float] = None) -> Request:
        """Queue one request.  ``tenant``/``priority`` drive weighted-fair
        batch assembly and the scheduler's space-sharing weights;
        ``deadline_s`` (seconds from now) makes the request's batch EDF-rank
        ahead of deadline-free work and carries into the scheduler's
        deadline-aware execution."""
        with self.sched.pipeline:
            req = Request(self._rid, np.asarray(tokens, np.int32),
                          new_tokens or self.max_new,
                          tenant=tenant, priority=priority,
                          deadline_s=deadline_s,
                          t_submit=self.sched.executor.host_now())
            self._rid += 1
            self._queue.append(req)
            return req

    # ------------------------------------------------------------------
    def _batch_fn(self, prompt_len: int, new_tokens: int) -> GrFunction:
        """The declared batch kernel for one (prompt_len, new_tokens) shape:
        const weights, const prompt tokens, out generated tokens."""
        key = (prompt_len, new_tokens)
        gf = self._fns.get(key)
        if gf is not None:
            return gf
        cfg = self.cfg
        max_len = prompt_len + new_tokens
        prefill, decode = self._prefill, self._decode

        def kernel(params, toks, _out):
            cache = init_cache(cfg, toks.shape[0], max_len)
            logits, cache = prefill(params, {"tokens": toks}, cache)
            nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            outs = [nxt]
            for i in range(new_tokens - 1):
                nxt, _, cache = decode(params, nxt, cache,
                                       jnp.int32(prompt_len + i))
                outs.append(nxt)
            return jnp.concatenate(outs, axis=1)

        # NOTE: the declared name is shape-keyed, not rid-keyed, so repeated
        # same-shape batches match one cached plan (and the kernel history
        # aggregates per shape).
        gf = function(kernel, modes=("const", "const", "out"),
                      name=f"serve_p{prompt_len}_n{new_tokens}",
                      scheduler=self.sched)
        self._fns[key] = gf
        return gf

    def flush(self, force: bool = False) -> None:
        """Assemble queued requests into fixed-shape batches and issue them
        through the scheduler (each batch = one lane-schedulable element).

        Batches are formed per (shape, tenant, priority) and issued in
        weighted-fair order: the tenant with the smallest virtual time goes
        next, and issuing one batch advances its clock by ``1/weight`` —
        priority-3 tenants therefore issue 8 batches for every priority-0
        batch while both have work queued, yet nobody starves.

        Deadline'd requests rank their batch earliest-deadline-first within
        the tenant, and an urgent head batch (finite deadline) issues ahead
        of the stride order; deadline-free flushes are bit-identical to the
        stride-only engine.  With ``max_batch_wait_s`` set, an under-full
        batch is *held* (requeued) until its oldest request has waited that
        long or a member's deadline is within the wait bound — late
        same-shape arrivals then fill it instead of padding.  ``force=True``
        issues everything regardless of age (drain/shutdown path)."""
        with self.sched.pipeline:
            wait = getattr(self, "max_batch_wait_s", None)
            now = self.sched.executor.host_now()
            by_key: Dict[tuple, List[Request]] = collections.defaultdict(list)
            while self._queue:
                r = self._queue.popleft()
                by_key[(len(r.tokens), r.new_tokens,
                        r.tenant, r.priority)].append(r)
            # Per-tenant queue of ready batches, highest priority first (a
            # tenant's priority-3 batch must not wait behind its own
            # priority-0 batch; the stride charge below then uses the right
            # weight) with shape as a deterministic tie-break.
            ready: Dict[str, collections.deque] = {}
            held: List[Request] = []
            for (plen, ntok, tenant, prio), reqs in sorted(
                    by_key.items(), key=lambda kv: (-kv[0][3], kv[0][:2])):
                # Stable deadline sort: urgent requests pack into the first
                # batch of their shape; deadline-free requests (all +inf)
                # keep FIFO arrival order.
                reqs.sort(key=lambda r: r.deadline_t)
                for i in range(0, len(reqs), self.batch):
                    group = reqs[i:i + self.batch]
                    edl = min(r.deadline_t for r in group)
                    if (wait is not None and not force
                            and len(group) < self.batch
                            and now - min(r.t_submit for r in group) < wait
                            and edl - now > wait):
                        held.extend(group)
                        continue
                    ready.setdefault(tenant, collections.deque()).append(
                        (edl, plen, ntok, prio, group))
            if held:
                self._queue.extendleft(reversed(held))
            if not ready:
                return
            # Within each tenant: earliest deadline first.  Stable, and all
            # deadline-free batches key at +inf, so a deadline-free flush
            # preserves the (-priority, shape) order built above exactly.
            ready = {t: collections.deque(sorted(dq, key=lambda b: b[0]))
                     for t, dq in ready.items()}
            # Stride scheduling over this flush's tenants.  Virtual time is
            # per-flush: every flush drains the whole queue, so there is no
            # standing backlog for cross-flush debt to arbitrate — and a
            # persisted vtime would let a long-idle tenant return anchored
            # to a stale minimum and claim an unbounded burst.
            vt = {t: 0.0 for t in ready}
            while any(ready.values()):
                live = [t for t in ready if ready[t]]
                # EDF across tenant heads while any head is deadline'd; the
                # stride clock below still charges the issue, so the
                # weighted-fair debt is settled once deadlines drain.
                if min(ready[t][0][0] for t in live) < float("inf"):
                    tenant = min(live, key=lambda t: (ready[t][0][0], t))
                else:
                    tenant = min(live, key=lambda t: (vt[t], t))
                _, plen, ntok, prio, group = ready[tenant].popleft()
                vt[tenant] += 1.0 / priority_weight(prio)
                self._issue_batch(plen, ntok, tenant, prio, group)

    def _issue_batch(self, plen: int, ntok: int, tenant: str, prio: int,
                     group: List[Request]) -> None:
        toks = np.stack([r.tokens for r in group])
        pad = self.batch - len(group)
        if pad:  # fixed shapes -> no retracing
            toks = np.concatenate(
                [toks, np.zeros((pad, plen), np.int32)])
        t_in = self.sched.array(toks, name=f"prompts_{group[0].rid}")
        t_out = self.sched.array(
            np.zeros((self.batch, ntok), np.int32),
            name=f"gen_{group[0].rid}")
        # Priority/tenant are call-scoped options and part of the plan
        # signature, so tenants never share a plan's weighting.
        opts = dict(priority=prio, tenant=tenant)
        dls = [r.deadline_s for r in group if r.deadline_s is not None]
        if dls:
            # The *declared* (relative) window, not the remaining slack:
            # deadline_s is part of the capture-plan signature, so a stable
            # value is what lets steady-state deadline'd batches keep
            # replaying one plan.  The absolute deadline_t is stamped at
            # launch, i.e. the window restarts at issue time.
            opts["deadline_s"] = min(dls)
        gf = self._batch_fn(plen, ntok).with_options(**opts)
        ctx = (self.sched.capture(gf.name) if self.capture
               else contextlib.nullcontext())
        with ctx:
            gf(self.params_v, t_in, t_out)
        self._pending.append((group, t_out))

    def collect(self) -> List[Request]:
        """Host-reads each batch's output (syncing only its lane) and
        attaches results to the requests."""
        done = []
        for group, t_out in self._pending:
            vals = np.asarray(t_out)
            for j, r in enumerate(group):
                r.result = vals[j]
                done.append(r)
        self._pending.clear()
        return done

    def stats(self) -> dict:
        return self.sched.stats()

    def tenant_stats(self) -> dict:
        """Per-tenant QoS (makespan, queueing delay, latency p50/p99)."""
        return self.sched.tenant_stats()

    # ------------------------------------------------------------------
    def drain(self) -> List[Request]:
        """Flush everything still queued (ignoring the batch-age hold) and
        collect every pending batch — no request left in flight."""
        done: List[Request] = []
        while self._queue or self._pending:
            if self._queue:
                self.flush(force=True)
            done.extend(self.collect())
        return done

    def close(self) -> None:
        """Drain in-flight work; close the scheduler only when the engine
        created it (a caller-supplied scheduler — e.g. the daemon's shared
        one — outlives any single engine)."""
        self.drain()
        if self._owns_sched:
            self.sched.close()
        else:
            self.sched.sync()

    def __enter__(self) -> "ServingEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
