"""Pipeline parallelism over the ``pod`` axis (GPipe-style).

The multi-pod mesh's slowest links are the inter-pod ones; instead of pure
DP over ``pod`` (per-step gradient reduce-scatter across pods), the layer
stack can be split into one *stage per pod* and microbatches streamed
through with ``ppermute`` handoffs — inter-pod traffic becomes one
activation tensor per microbatch instead of the full gradient set.

Implementation: ``shard_map`` over the pipeline axis; every rank runs the
same program on its own stage parameters (stacked with a leading
``n_stages`` axis sharded over the pipeline axis).  The classic GPipe
schedule is expressed as a ``lax.scan`` over ``n_micro + n_stages - 1``
ticks: each tick computes the local stage on the activation received last
tick and ppermutes the result to the next rank.  Bubble fraction =
(S-1)/(T+S-1), recovered in §Perf napkin math.

Used by tests/test_pipeline.py (fake 8-device mesh) and exposed as a
building block; the 40-cell dry-run keeps DP over ``pod`` as its default
(better for the assigned global-batch shapes), with PP available via this
module for deeper-than-HBM models.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(stage_fn: Callable, mesh: Mesh, axis: str = "pod"):
    """Build a pipelined forward: ``f(stage_params, x_micro) -> y_micro``.

    * ``stage_params``: pytree whose leaves have a leading ``n_stages`` axis,
      sharded over ``axis`` (one stage per rank group).
    * ``x_micro``: (n_micro, micro_batch, ...) — replicated along ``axis``.
    * ``stage_fn(params_stage, x) -> x`` applies one stage.

    Returns outputs (n_micro, micro_batch, ...) valid on the LAST stage
    (other ranks return garbage of the right shape; callers psum-select).
    """
    n_stages = mesh.shape[axis]

    def ranked(params, xs):
        rank = jax.lax.axis_index(axis)
        params = jax.tree_util.tree_map(lambda a: a[0], params)  # local stage
        n_micro = xs.shape[0]
        ticks = n_micro + n_stages - 1
        fwd = [(i, (i + 1) % n_stages) for i in range(n_stages - 1)]

        def tick(carry, t):
            inflight, outputs = carry
            # which microbatch enters the pipe this tick (stage 0 only)
            enter = jnp.where(t < n_micro, t, 0)
            x_in = jnp.where(rank == 0, xs[enter], inflight)
            y = stage_fn(params, x_in)
            # hand off to the next stage
            handed = jax.lax.ppermute(y, axis, fwd) if n_stages > 1 else y
            # last stage commits an output for microbatch t-(S-1)
            out_idx = t - (n_stages - 1)
            commit = (rank == n_stages - 1) & (out_idx >= 0)
            outputs = jax.lax.cond(
                commit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(out_idx, 0), 0),
                lambda o: o, outputs)
            return (handed, outputs), None

        inflight0 = jnp.zeros_like(xs[0])
        outputs0 = jnp.zeros_like(xs)
        (_, outputs), _ = jax.lax.scan(tick, (inflight0, outputs0),
                                       jnp.arange(ticks))
        # broadcast the last stage's outputs to every rank
        outputs = jax.lax.psum(
            jnp.where(rank == n_stages - 1, outputs, 0.0), axis)
        return outputs

    # P(axis) acts as a prefix spec for the whole parameter pytree: every
    # leaf is sharded on its leading (stage) dim; activations replicated.
    return shard_map(ranked, mesh=mesh, in_specs=(P(axis), P()),
                     out_specs=P(), check_rep=False)


def pipeline_loss_fn(stage_fn: Callable, loss_tail: Callable, mesh: Mesh,
                     axis: str = "pod"):
    """Differentiable pipelined loss: mean over microbatches of
    ``loss_tail(last_stage_output, labels)``.  jax.grad flows through the
    ppermute schedule (GPipe's recompute-free backward)."""
    fwd = pipeline_apply(stage_fn, mesh, axis)

    def loss(stage_params, xs, ys):
        outs = fwd(stage_params, xs)
        return loss_tail(outs, ys)

    return loss
