"""Mixture-of-Experts FFN (dbrx 16e/top-4; qwen2-moe 60e/top-4 + shared).

Capacity-based dispatch via scatter/gather (``segment``-style) rather than
one-hot einsums: dispatch cost stays O(T·k·d) instead of O(T·E·C·d), so the
compiled FLOPs reflect useful work (important for the roofline's
MODEL_FLOPS/HLO_FLOPs ratio).  Expert weights carry a leading expert axis —
sharded over the mesh "model" axis when divisible (expert parallelism).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..sharding.context import shard_activations, use_weight
from .layers import apply_mlp, init_mlp, normal_init


def init_moe(key, cfg, dtype=jnp.float32):
    e = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    gated = cfg.mlp in ("swiglu", "geglu")
    p = {
        "router": normal_init(ks[0], (d, e.n_experts), dtype=jnp.float32),
        "w_in": normal_init(ks[1], (e.n_experts, d, e.d_ff_expert), dtype=dtype),
        "w_out": normal_init(ks[2], (e.n_experts, e.d_ff_expert, d), dtype=dtype),
    }
    if gated:
        p["w_gate"] = normal_init(ks[3], (e.n_experts, d, e.d_ff_expert),
                                  dtype=dtype)
    if e.n_shared_experts:

        class _C:  # minimal cfg view for the shared FFN
            mlp = cfg.mlp
            n_layers = cfg.n_layers
        p["shared"] = init_mlp(ks[4], _C, d,
                               e.d_ff_expert * e.n_shared_experts, dtype=dtype)
    return p


_EP_IN = (("model", None, None), (None, None, "model"))
_EP_OUT = (("model", None, None), (None, "model", None))


def _expert_ffn(cfg, p, x):
    """x: (B, E, C, d) -> (B, E, C, d), batched over group + expert axes.
    Expert weights are constrained to EP (expert axis over "model") when
    the expert count divides, else to TP on the expert FFN dim."""
    h = jnp.einsum("becd,edf->becf", x, use_weight(p["w_in"].astype(x.dtype),
                                                   *_EP_IN))
    if cfg.mlp == "swiglu":
        g = jnp.einsum("becd,edf->becf", x,
                       use_weight(p["w_gate"].astype(x.dtype), *_EP_IN))
        h = jax.nn.silu(g) * h
    elif cfg.mlp == "geglu":
        g = jnp.einsum("becd,edf->becf", x,
                       use_weight(p["w_gate"].astype(x.dtype), *_EP_IN))
        h = jax.nn.gelu(g, approximate=True) * h
    elif cfg.mlp == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h, approximate=True)
    return jnp.einsum("becf,efd->becd", h,
                      use_weight(p["w_out"].astype(x.dtype), *_EP_OUT))


def apply_moe(cfg, p, x) -> Tuple[jnp.ndarray, dict]:
    """x: (B, S, d) -> (out, aux) with load-balance/z losses in aux.

    Dispatch is *grouped by batch row*: each sample scatters its own tokens
    into per-expert buffers (capacity enforced per group, Switch-style).
    Because the group axis is the data-sharded batch axis, the
    scatter/gather never crosses devices — GSPMD keeps dispatch local and
    the only collectives are the (small) expert-weight gathers.  A global
    buffer here previously cost a 960 GiB fp32 all-reduce per step.
    """
    e = cfg.moe
    B, S, d = x.shape

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        use_weight(p["router"], (None, None)))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, e.top_k)     # (B, S, k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)

    # capacity per expert per group (= batch row)
    cap = int(max(e.top_k, S * e.top_k * e.capacity_factor / e.n_experts))
    cap = min(cap, S)
    Tk = S * e.top_k

    flat_ids = expert_ids.reshape(B, Tk)                       # (B, S*k)
    # position of each routed token within its expert's queue, via sort:
    # O(Tk log Tk) and O(Tk) memory instead of the O(Tk x E) one-hot cumsum
    order = jnp.argsort(flat_ids, axis=1, stable=True)
    sorted_ids = jnp.take_along_axis(flat_ids, order, axis=1)
    iota_e = jnp.arange(e.n_experts, dtype=flat_ids.dtype)
    counts = jnp.sum(flat_ids[:, :, None] == iota_e[None, None], axis=1)
    starts = jnp.cumsum(counts, axis=1) - counts               # (B, E)
    ranks_sorted = jnp.arange(Tk, dtype=flat_ids.dtype)[None, :] \
        - jnp.take_along_axis(starts, sorted_ids, axis=1)
    pos = jnp.zeros_like(flat_ids)
    pos = jnp.take_along_axis(
        pos.at[jnp.arange(B)[:, None], order].set(ranks_sorted),
        jnp.arange(Tk)[None, :], axis=1)
    keep = pos < cap                                           # drop overflow
    # overflow tokens get an out-of-bounds sentinel: the scatter drops them
    # (mode='drop') and the gather back fills zeros (mode='fill').  With
    # unique in-bounds indices + explicit vmap batching dims GSPMD keeps the
    # whole dispatch local to each data shard — ZERO collectives (a trash-row
    # formulation previously cost a ~1 TiB all-gather per step).
    slot = jnp.where(keep, flat_ids * cap + pos, e.n_experts * cap)

    xrep = shard_activations(
        jnp.repeat(x.reshape(B, S, d), e.top_k, axis=1))       # (B, S*k, d)
    slot = shard_activations(slot)
    buf = shard_activations(jnp.zeros((B, e.n_experts * cap, d), x.dtype))
    buf = shard_activations(jax.vmap(lambda b, idx, val: b.at[idx].set(
        val, mode="drop", unique_indices=True))(buf, slot, xrep))
    expert_in = buf.reshape(B, e.n_experts, cap, d)

    expert_out = _expert_ffn(cfg, p, expert_in)

    # gather back + combine with gates (batched gather, local per shard)
    flat_out = shard_activations(expert_out.reshape(B, e.n_experts * cap, d))
    routed = shard_activations(jax.vmap(lambda f, idx: f.at[idx].get(
        mode="fill", fill_value=0))(flat_out, slot))
    gates = (gate_vals.reshape(B, Tk) * keep).astype(x.dtype)
    combined = jnp.sum((routed * gates[..., None]).reshape(B, S, e.top_k, d),
                       axis=2)

    if e.n_shared_experts:
        class _C:
            mlp = cfg.mlp
            n_layers = cfg.n_layers
        combined = combined + apply_mlp(_C, p["shared"], x.reshape(B * S, d)
                                        ).reshape(B, S, d)

    # aux losses (Switch-style load balance + router z-loss)
    density = jnp.mean(jax.nn.one_hot(expert_ids, e.n_experts,
                                      dtype=jnp.float32), axis=(0, 1, 2))
    density_proxy = jnp.mean(probs, axis=(0, 1))
    aux = {
        "moe_aux": e.n_experts * jnp.sum(density * density_proxy) * e.aux_loss,
        "moe_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * e.router_z_loss,
    }
    return combined, aux
