"""Mamba-style selective SSM head for the Hymba hybrid blocks
(arXiv:2411.13676: parallel attention + SSM heads, ssm_state=16).

Diagonal selective scan (S6): per channel c and state n
    h_t = exp(-Δ_t A) ⊙ h_{t-1} + Δ_t B_t u_t
    y_t = C_t · h_t + D u_t
with Δ, B, C input-dependent.  Sequence mode uses an associative scan over
time (log-depth, TPU-friendly); decode mode is an O(1) state update.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..sharding.context import use_weight
from .layers import normal_init


def init_mamba(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    s = cfg.ssm
    inner = s.expand * d
    ks = jax.random.split(key, 7)
    return {
        "w_in": normal_init(ks[0], (d, 2 * inner), dtype=dtype),   # u, z
        "w_dt": normal_init(ks[1], (inner, 1), scale=0.1, dtype=dtype),
        "dt_bias": jnp.zeros((inner,), dtype),
        "w_B": normal_init(ks[2], (inner, s.state_dim), dtype=dtype),
        "w_C": normal_init(ks[3], (inner, s.state_dim), dtype=dtype),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, s.state_dim + 1,
                                             dtype=jnp.float32)[None, :],
                                  (inner, 1))).astype(dtype),
        "D": jnp.ones((inner,), dtype),
        "conv_w": normal_init(ks[4], (s.conv_dim, inner), scale=0.2,
                              dtype=dtype),
        "w_out": normal_init(ks[5], (inner, d), dtype=dtype),
    }


def init_mamba_state(cfg, batch: int, dtype=jnp.float32):
    s = cfg.ssm
    inner = s.expand * cfg.d_model
    return {"h": jnp.zeros((batch, inner, s.state_dim), jnp.float32),
            "conv": jnp.zeros((batch, s.conv_dim - 1, inner), dtype)}


def _features(cfg, p, u_conv):
    """Input-dependent SSM parameters from the conv'd activation."""
    dt = jax.nn.softplus(u_conv * p["w_dt"].astype(u_conv.dtype)[:, 0]
                         + p["dt_bias"].astype(u_conv.dtype))
    B = u_conv @ p["w_B"].astype(u_conv.dtype)
    C = u_conv @ p["w_C"].astype(u_conv.dtype)
    return dt, B, C


def _causal_conv_seq(p, u, conv_state):
    """Depthwise causal conv over time. u: (B,S,inner)."""
    k = p["conv_w"].shape[0]
    pad = jnp.concatenate([conv_state.astype(u.dtype), u], axis=1)        # (B, S+k-1, inner)
    out = jnp.zeros_like(u)
    cw = p["conv_w"].astype(u.dtype)
    for i in range(k):
        out = out + pad[:, i:i + u.shape[1], :] * cw[i][None, None, :]
    return jax.nn.silu(out), pad[:, -(k - 1):, :]


def apply_mamba_seq(cfg, p, x, state) -> Tuple[jnp.ndarray, dict]:
    """x: (B, S, d). Associative scan over time in fp32."""
    B, S, d = x.shape
    s = cfg.ssm
    inner = s.expand * d
    uz = x @ use_weight(p["w_in"].astype(x.dtype), (None, "model"))
    u, z = uz[..., :inner], uz[..., inner:]
    u, conv_state = _causal_conv_seq(p, u, state["conv"])
    dt, Bm, Cm = _features(cfg, p, u)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))                    # (inner,N)
    dt32 = dt.astype(jnp.float32)
    decay = jnp.exp(dt32[..., None] * A[None, None])                # (B,S,i,N)
    drive = (dt32 * u.astype(jnp.float32))[..., None] \
        * Bm.astype(jnp.float32)[:, :, None, :]                     # (B,S,i,N)

    # h_t = decay_t * h_{t-1} + drive_t  — associative over t
    def combine(a, b):
        da, xa = a
        db, xb = b
        return da * db, xb + db * xa

    # chunked associative scan: the (B,S,inner,N) state trajectory never
    # materializes for the full sequence — bounded at chunk granularity,
    # chunk boundaries checkpointed for the backward pass.
    chunk = 256
    while S % chunk:
        chunk //= 2
    n_chunks = S // chunk

    def chunk_body(h0, xs):
        dchunk, xchunk, cchunk = xs                        # (B,c,i,N) x2
        d0 = jnp.concatenate([jnp.ones_like(dchunk[:, :1]), dchunk], axis=1)
        x0 = jnp.concatenate([h0[:, None], xchunk], axis=1)
        _, hs = jax.lax.associative_scan(combine, (d0, x0), axis=1)
        hs = hs[:, 1:]
        yc = jnp.einsum("bsin,bsn->bsi", hs, cchunk)
        return hs[:, -1], yc

    chunk_body = jax.checkpoint(chunk_body, prevent_cse=False)
    dc = decay.reshape(B, n_chunks, chunk, inner, -1).swapaxes(0, 1)
    xc = drive.reshape(B, n_chunks, chunk, inner, -1).swapaxes(0, 1)
    cc = Cm.astype(jnp.float32).reshape(B, n_chunks, chunk, -1).swapaxes(0, 1)
    h_last, ys = jax.lax.scan(chunk_body, state["h"], (dc, xc, cc))
    y = ys.swapaxes(0, 1).reshape(B, S, inner)
    y = y.astype(x.dtype) + u * p["D"].astype(x.dtype)[None, None]
    y = y * jax.nn.silu(z)
    out = y @ use_weight(p["w_out"].astype(x.dtype), ("model", None))
    return out, {"h": h_last, "conv": conv_state.astype(jnp.float32)}


def apply_mamba_step(cfg, p, x, state) -> Tuple[jnp.ndarray, dict]:
    """x: (B, 1, d) decode — O(1) update."""
    B, _, d = x.shape
    s = cfg.ssm
    inner = s.expand * d
    uz = x[:, 0] @ use_weight(p["w_in"].astype(x.dtype), (None, "model"))
    u_raw, z = uz[..., :inner], uz[..., inner:]
    k = p["conv_w"].shape[0]
    window = jnp.concatenate([state["conv"].astype(x.dtype), u_raw[:, None, :]], axis=1)
    u = jax.nn.silu(jnp.einsum("bki,ki->bi", window, p["conv_w"].astype(x.dtype)))
    dt, Bm, Cm = _features(cfg, p, u)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt32 = dt.astype(jnp.float32)
    decay = jnp.exp(dt32[..., None] * A[None])
    h = decay * state["h"] + (dt32 * u.astype(jnp.float32))[..., None] \
        * Bm.astype(jnp.float32)[:, None, :]
    y = jnp.einsum("bin,bn->bi", h, Cm.astype(jnp.float32)).astype(x.dtype)
    y = y + u * p["D"].astype(x.dtype)[None]
    y = y * jax.nn.silu(z)
    out = (y @ use_weight(p["w_out"].astype(x.dtype), ("model", None)))[:, None, :]
    return out, {"h": h, "conv": window[:, 1:, :].astype(jnp.float32)}
