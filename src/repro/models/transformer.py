"""Unified LM stack for all 10 assigned architectures.

Layers are scanned in *groups* (``cfg.layer_group``): uniform stacks scan
layer-by-layer; gemma3's 5-local:1-global pattern scans groups of six with
static per-position window flags.  Parameters and caches carry a leading
``n_groups`` axis so the whole stack lowers to one rolled ``lax.scan`` —
essential to keep the 96-layer/340B HLO small enough to compile.

Three entry points per model:
* ``forward_train``  — full-sequence logits (+ MoE aux losses);
* ``forward_prefill``— full sequence, returns last-token logits + caches;
* ``forward_decode`` — one token against caches (KV / RWKV / Mamba state).
"""
from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from . import attention as A
from . import mamba as M
from . import moe as MOE
from . import rwkv as R
from .config import ArchConfig
from ..sharding.context import shard_activations, use_weight
from .layers import apply_mlp, apply_norm, init_mlp, init_norm, normal_init


# ----------------------------------------------------------------------
# per-position layer kinds within one scan group
# ----------------------------------------------------------------------
def layer_kinds(cfg: ArchConfig) -> List[str]:
    if cfg.family == "ssm":
        return ["rwkv"]
    if cfg.family == "hybrid":
        return ["hymba"]
    g = cfg.layer_group
    if g > 1:  # local:global pattern (gemma3: 5 local then 1 global)
        return ["attn_local"] * (g - 1) + ["attn_global"]
    if cfg.sliding_window > 0 and cfg.global_every == 0:
        return ["attn_local"]
    return ["attn_global"]


def _uses_moe(cfg: ArchConfig) -> bool:
    return cfg.moe is not None


# ----------------------------------------------------------------------
# block init
# ----------------------------------------------------------------------
def init_block(key, cfg: ArchConfig, kind: str, dtype=jnp.float32,
               cross: bool = False) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {"ln1": init_norm(cfg, cfg.d_model, dtype)}
    if kind == "rwkv":
        p["rwkv"] = R.init_rwkv(ks[0], cfg, dtype)
        p["ln2"] = init_norm(cfg, cfg.d_model, dtype)
        p["cmix"] = R.init_channel_mix(ks[1], cfg, dtype)
        return p
    p["attn"] = A.init_attention(ks[0], cfg, dtype)
    if kind == "hymba":
        p["mamba"] = M.init_mamba(ks[1], cfg, dtype)
    if cross:
        p["ln_cross"] = init_norm(cfg, cfg.d_model, dtype)
        p["cross"] = A.init_attention(ks[2], cfg, dtype, cross=True)
    p["ln2"] = init_norm(cfg, cfg.d_model, dtype)
    if _uses_moe(cfg):
        p["moe"] = MOE.init_moe(ks[3], cfg, dtype)
    else:
        p["ffn"] = init_mlp(ks[3], cfg, cfg.d_model, cfg.d_ff, dtype)
    return p


def init_block_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int,
                     cross_len: int = 0, dtype=jnp.bfloat16):
    if kind == "rwkv":
        st = R.init_rwkv_state(cfg, batch)
        st["cmix_shift"] = jnp.zeros((batch, cfg.d_model), jnp.float32)
        return st
    # sliding-window layers only ever attend to the last `window` keys:
    # their cache is a ring buffer of that size (a 32k gemma3/hymba cache
    # would otherwise be ~40x larger than needed)
    if kind in ("attn_local", "hymba") and cfg.sliding_window > 0:
        max_len = min(max_len, cfg.sliding_window)
    c: Dict[str, Any] = dict(A.init_kv_cache(cfg, batch, max_len, dtype))
    if kind == "hymba":
        c["mamba"] = M.init_mamba_state(cfg, batch)
    if cross_len:
        c["cross_k"] = jnp.zeros((batch, cross_len, cfg.n_kv_heads, cfg.hd), dtype)
        c["cross_v"] = jnp.zeros((batch, cross_len, cfg.n_kv_heads, cfg.hd), dtype)
    return c


# ----------------------------------------------------------------------
# block apply
# ----------------------------------------------------------------------
def _ffn_part(cfg, p, x, aux):
    h = apply_norm(cfg, p["ln2"], x)
    if "moe" in p:
        y, a = MOE.apply_moe(cfg, p["moe"], h)
        aux = (aux[0] + a["moe_aux"], aux[1] + a["moe_z"])
    else:
        y = apply_mlp(cfg, p["ffn"], h)
    return x + y, aux


def apply_block_seq(cfg, kind, p, x, positions, aux, *, cache=None,
                    enc_out=None, bidirectional=False, use_flash=False):
    """Full-sequence mode. Returns (x, aux, new_cache)."""
    h = apply_norm(cfg, p["ln1"], x)
    new_cache = None
    if kind == "rwkv":
        y, st = R.apply_rwkv_seq(cfg, p["rwkv"], h, cache if cache is not None
                                 else R.init_rwkv_state(cfg, x.shape[0]))
        x = x + y
        h2 = apply_norm(cfg, p["ln2"], x)
        y2, cshift = R.apply_channel_mix(
            cfg, p["cmix"], h2,
            cache["cmix_shift"] if cache is not None
            else jnp.zeros((x.shape[0], cfg.d_model), jnp.float32))
        st["cmix_shift"] = cshift.astype(jnp.float32)
        return x + y2, aux, st

    window = cfg.sliding_window if kind == "attn_local" or kind == "hymba" else 0
    y, (k, v) = A.attend_full(cfg, p["attn"], h, positions, window=window,
                              use_flash=use_flash, bidirectional=bidirectional)
    if kind == "hymba":
        ym, mstate = M.apply_mamba_seq(
            cfg, p["mamba"], h,
            cache["mamba"] if cache is not None
            else M.init_mamba_state(cfg, x.shape[0]))
        y = 0.5 * (y + ym)
    x = x + y
    if "cross" in p and enc_out is not None:
        hc = apply_norm(cfg, p["ln_cross"], x)
        x = x + A.attend_cross(cfg, p["cross"], hc, enc_out)
    x, aux = _ffn_part(cfg, p, x, aux)
    if cache is not None:
        S = k.shape[1]
        W = cache["k"].shape[1]
        new_cache = dict(cache)
        if W < S:
            # ring buffer: token t lives at slot t % W
            kt = k[:, S - W:].astype(cache["k"].dtype)
            vt = v[:, S - W:].astype(cache["v"].dtype)
            shift = S % W
            new_cache["k"] = jnp.roll(kt, shift, axis=1)
            new_cache["v"] = jnp.roll(vt, shift, axis=1)
        else:
            new_cache["k"] = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
            new_cache["v"] = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
        if kind == "hymba":
            new_cache["mamba"] = mstate
        if "cross" in p and enc_out is not None:
            _, ck, cv = A._project_qkv(cfg, p["cross"], x, enc_out)
            new_cache["cross_k"] = ck.astype(cache["cross_k"].dtype)
            new_cache["cross_v"] = cv.astype(cache["cross_v"].dtype)
    return x, aux, new_cache


def apply_block_decode(cfg, kind, p, x, cache, pos, aux):
    """Single-token mode. Returns (x, aux, new_cache)."""
    h = apply_norm(cfg, p["ln1"], x)
    if kind == "rwkv":
        y, st = R.apply_rwkv_step(cfg, p["rwkv"], h, cache)
        x = x + y
        h2 = apply_norm(cfg, p["ln2"], x)
        y2, cshift = R.apply_channel_mix(cfg, p["cmix"], h2,
                                         cache["cmix_shift"].astype(x.dtype))
        st["cmix_shift"] = cshift.astype(jnp.float32)
        return x + y2, aux, st

    window = cfg.sliding_window if kind in ("attn_local", "hymba") else 0
    y, kv = A.attend_decode(cfg, p["attn"], h, cache, pos, window=window)
    new_cache = dict(cache)
    new_cache.update(kv)
    if kind == "hymba":
        ym, mstate = M.apply_mamba_step(cfg, p["mamba"], h, cache["mamba"])
        y = 0.5 * (y + ym)
        new_cache["mamba"] = mstate
    x = x + y
    if "cross" in p:
        hc = apply_norm(cfg, p["ln_cross"], x)
        o = A._sdpa(cfg,
                    (hc @ p["cross"]["wq"].astype(x.dtype)).reshape(
                        x.shape[0], 1, cfg.n_heads, cfg.hd),
                    cache["cross_k"].astype(x.dtype),
                    cache["cross_v"].astype(x.dtype), None)
        x = x + o @ p["cross"]["wo"].astype(x.dtype)
    x, aux = _ffn_part(cfg, p, x, aux)
    return x, aux, new_cache


# ----------------------------------------------------------------------
# whole-model init
# ----------------------------------------------------------------------
def init_lm(key, cfg: ArchConfig, dtype=jnp.float32):
    kinds = layer_kinds(cfg)
    g = len(kinds)
    n_groups = cfg.n_layers // g
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": normal_init(ks[0], (cfg.vocab, cfg.d_model), dtype=dtype),
        "final_norm": init_norm(cfg, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = normal_init(ks[1], (cfg.d_model, cfg.vocab),
                                        dtype=dtype)

    cross = cfg.n_encoder_layers > 0

    def stack(key, kind, cross_flag):
        keys = jax.random.split(key, n_groups)
        return jax.vmap(lambda k: init_block(k, cfg, kind, dtype, cross_flag)
                        )(keys)

    params["blocks"] = tuple(
        stack(jax.random.fold_in(ks[2], i), kind, cross)
        for i, kind in enumerate(kinds))

    if cross:  # encoder stack (seamless)
        enc_keys = jax.random.split(ks[3], cfg.n_encoder_layers)
        params["encoder"] = jax.vmap(
            lambda k: init_block(k, cfg, "attn_global", dtype, False)
        )(enc_keys)
        params["enc_norm"] = init_norm(cfg, cfg.d_model, dtype)
        params["frontend_proj"] = normal_init(ks[4], (cfg.d_model, cfg.d_model),
                                              dtype=dtype)
    if cfg.frontend == "vision":
        params["frontend_proj"] = normal_init(ks[4], (cfg.d_model, cfg.d_model),
                                              dtype=dtype)
    return params


def init_cache(cfg: ArchConfig, batch: int, max_len: int, cross_len: int = 0,
               dtype=jnp.bfloat16):
    kinds = layer_kinds(cfg)
    g = len(kinds)
    n_groups = cfg.n_layers // g

    def stacked(kind):
        one = init_block_cache(cfg, kind, batch, max_len, cross_len, dtype)
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (n_groups,) + a.shape), one)

    return tuple(stacked(kind) for kind in kinds)


# ----------------------------------------------------------------------
# forward passes
# ----------------------------------------------------------------------
def _embed(cfg, params, tokens, frontend=None):
    table = use_weight(params["embed"].astype(jnp.bfloat16), ("model", None))
    x = table[tokens]
    x = shard_activations(x)
    if cfg.frontend == "vision" and frontend is not None:
        fp = frontend.astype(x.dtype) @ params["frontend_proj"].astype(x.dtype)
        x = jax.lax.dynamic_update_slice(x, fp, (0, 0, 0))
    return x


def _encode(cfg, params, frames):
    """Seamless encoder: frames (B, S_enc, d) from the audio-frontend stub."""
    x = frames.astype(jnp.bfloat16) @ params["frontend_proj"].astype(jnp.bfloat16)
    positions = jnp.arange(x.shape[1])[None, :]
    aux = (jnp.float32(0), jnp.float32(0))

    def body(carry, blk):
        x, aux = carry
        x, aux, _ = apply_block_seq(cfg, "attn_global", blk, x, positions, aux,
                                    bidirectional=True)
        return (x, aux), None

    (x, _), _ = jax.lax.scan(body, (x, aux), params["encoder"])
    return apply_norm(cfg, params["enc_norm"], x)


def _logits(cfg, params, x):
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])
    head = use_weight(head.astype(x.dtype), (None, "model"))
    return (x @ head).astype(jnp.float32)


def forward_train(cfg: ArchConfig, params, batch, use_flash: bool = False,
                  remat: bool = False, seq_shard: bool = False):
    """batch: dict(tokens (B,S) int32, + optional frames/patches).
    Returns (logits_f32 (B,S,V), aux dict).  ``remat=True`` checkpoints each
    scanned layer group (recompute in backward) to bound activation memory.
    """
    tokens = batch["tokens"]
    enc_out = None
    if cfg.n_encoder_layers:
        enc_out = _encode(cfg, params, batch["frames"])
    x = _embed(cfg, params, tokens, batch.get("patches"))
    positions = jnp.arange(tokens.shape[1])[None, :]
    aux = (jnp.float32(0), jnp.float32(0))
    kinds = layer_kinds(cfg)
    # Megatron-style sequence parallelism: the residual stream (and hence
    # the remat-saved layer inputs) is sharded over "model" along the
    # sequence dim between blocks; GSPMD inserts the gather at attention.
    seq_ax = "model" if seq_shard else None
    if seq_shard:
        x = shard_activations(x, seq_axis=seq_ax)

    def body(carry, blk_params):
        x, aux = carry
        for i, kind in enumerate(kinds):
            p_i = jax.tree_util.tree_map(lambda a: a, blk_params[i])
            x, aux, _ = apply_block_seq(cfg, kind, p_i, x, positions, aux,
                                        enc_out=enc_out, use_flash=use_flash)
            if seq_shard:
                x = shard_activations(x, seq_axis=seq_ax)
        return (x, aux), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, aux), params["blocks"])
    x = apply_norm(cfg, params["final_norm"], x)
    return _logits(cfg, params, x), {"moe_aux": aux[0], "moe_z": aux[1]}


def forward_prefill(cfg: ArchConfig, params, batch, cache,
                    use_flash: bool = False):
    """Full-sequence prefill that fills the caches.
    Returns (last-token logits (B,V), new_cache)."""
    tokens = batch["tokens"]
    enc_out = None
    if cfg.n_encoder_layers:
        enc_out = _encode(cfg, params, batch["frames"])
    x = _embed(cfg, params, tokens, batch.get("patches"))
    positions = jnp.arange(tokens.shape[1])[None, :]
    aux = (jnp.float32(0), jnp.float32(0))
    kinds = layer_kinds(cfg)

    # the cache rides in the scan CARRY and is updated slice-by-slice in
    # place: xs/ys caches would be double-buffered by XLA (2x cache HBM)
    def body(carry, xs):
        x, aux, cache_full = carry
        blk_params, g = xs
        new_caches = []
        for i, kind in enumerate(kinds):
            cache_g = jax.tree_util.tree_map(lambda c: c[g], cache_full[i])
            x, aux, nc = apply_block_seq(cfg, kind, blk_params[i], x,
                                         positions, aux, cache=cache_g,
                                         enc_out=enc_out, use_flash=use_flash)
            new_caches.append(jax.tree_util.tree_map(
                lambda c, n: jax.lax.dynamic_update_index_in_dim(
                    c, n.astype(c.dtype), g, 0), cache_full[i], nc))
        return (x, aux, tuple(new_caches)), None

    n_groups = cfg.n_layers // len(kinds)
    (x, _, new_cache), _ = jax.lax.scan(
        body, (x, aux, cache), (params["blocks"], jnp.arange(n_groups)))
    x = apply_norm(cfg, params["final_norm"], x[:, -1:, :])
    return _logits(cfg, params, x)[:, 0], new_cache


def forward_decode(cfg: ArchConfig, params, tokens, cache, pos):
    """tokens: (B, 1); pos: scalar int32 index of the new token.
    Returns (logits (B, V), new_cache)."""
    x = _embed(cfg, params, tokens)
    aux = (jnp.float32(0), jnp.float32(0))
    kinds = layer_kinds(cfg)

    def body(carry, xs):
        x, aux, cache_full = carry
        blk_params, g = xs
        new_caches = []
        for i, kind in enumerate(kinds):
            cache_g = jax.tree_util.tree_map(lambda c: c[g], cache_full[i])
            x, aux, nc = apply_block_decode(cfg, kind, blk_params[i], x,
                                            cache_g, pos, aux)
            new_caches.append(jax.tree_util.tree_map(
                lambda c, n: jax.lax.dynamic_update_index_in_dim(
                    c, n.astype(c.dtype), g, 0), cache_full[i], nc))
        return (x, aux, tuple(new_caches)), None

    n_groups = cfg.n_layers // len(kinds)
    (x, _, new_cache), _ = jax.lax.scan(
        body, (x, aux, cache), (params["blocks"], jnp.arange(n_groups)))
    x = apply_norm(cfg, params["final_norm"], x)
    return _logits(cfg, params, x)[:, 0], new_cache


# ----------------------------------------------------------------------
def cross_entropy_loss(logits, labels, z_loss: float = 1e-4):
    """logits (B,S,V) f32; labels (B,S) int32; returns scalar mean loss.

    The gold logit is picked with a one-hot reduction rather than
    ``take_along_axis``: a vocab-dim gather would force GSPMD to all-gather
    the (B,S,V) logits across the TP axis, while the compare-and-reduce
    stays sharded (verified in the dry-run collective table).
    """
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab = logits.shape[-1]
    onehot = labels[..., None] == jnp.arange(vocab, dtype=labels.dtype)
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    loss = jnp.mean(lse - gold)
    if z_loss:
        loss = loss + z_loss * jnp.mean(lse ** 2)
    return loss
