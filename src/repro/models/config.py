"""Architecture configuration for the model zoo.

One `ArchConfig` covers every assigned architecture family: dense decoder
transformers (GQA/RoPE/sliding-window/qk-norm/squared-ReLU), MoE, RWKV6,
hybrid attention+SSM (Hymba), encoder-decoder (Seamless) and modality-stub
backbones (InternVL, Seamless audio).  `src/repro/configs/<id>.py` files
instantiate the exact published configs; `reduced()` derives the smoke-test
versions.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0        # qwen2-moe: shared experts (always-on)
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    aux_loss: float = 1e-2


@dataclass(frozen=True)
class SSMConfig:
    kind: str = "rwkv6"              # "rwkv6" | "mamba"
    head_dim: int = 64               # rwkv6 head size
    state_dim: int = 16              # mamba state per channel (hymba ssm_state)
    expand: int = 2                  # mamba inner expansion
    conv_dim: int = 4                # mamba depthwise conv width


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # default: d_model // n_heads
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    mlp: str = "swiglu"              # swiglu | geglu | gelu | relu2
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    # local/global attention pattern (gemma3): window size for local layers,
    # one global layer every `global_every` layers (0 = all global).
    sliding_window: int = 0
    global_every: int = 0
    logit_softcap: float = 0.0
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # encoder-decoder (seamless): encoder layer count; frontend stub kind
    n_encoder_layers: int = 0
    frontend: Optional[str] = None   # "audio" | "vision" | None
    n_frontend_tokens: int = 0       # patches / frames provided by the stub
    # ------------------------------------------------------------------
    source: str = ""                 # provenance note ([arXiv/hf; tier])

    # -- derived ---------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def q_groups(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k shape (DESIGN.md §4)."""
        return (self.family in ("ssm", "hybrid")
                or (self.sliding_window > 0 and self.global_every > 0))

    @property
    def layer_group(self) -> int:
        """Layers per scan group (local/global patterns repeat every
        `global_every`; uniform stacks scan layer-by-layer)."""
        return self.global_every if self.global_every > 1 else 1

    # -- parameter counting (for roofline MODEL_FLOPS) --------------------
    def param_count(self, active_only: bool = False) -> int:
        d, ff, hd = self.d_model, self.d_ff, self.hd
        qkv = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd)
        o = self.n_heads * hd * d
        attn = qkv + o
        gates = 3 if self.mlp in ("swiglu", "geglu") else 2
        if self.moe:
            e = self.moe
            ff_all = e.n_experts * gates * d * e.d_ff_expert + d * e.n_experts
            ff_act = e.top_k * gates * d * e.d_ff_expert + d * e.n_experts
            if e.n_shared_experts:
                shared = gates * d * e.d_ff_expert * e.n_shared_experts
                ff_all += shared
                ff_act += shared
        else:
            ff_all = ff_act = gates * d * ff
        if self.family == "ssm":                       # rwkv6 time+channel mix
            attn = 5 * d * d + d * d // 2              # r,k,v,g,o + lora/decay
            ff_all = ff_act = 2 * d * self.d_ff
        if self.family == "hybrid" and self.ssm:
            inner = self.ssm.expand * d
            attn += 2 * d * inner + inner * (2 * self.ssm.state_dim + 1)
        per_layer = attn + (ff_act if active_only else ff_all)
        total = self.n_layers * per_layer
        total += self.n_encoder_layers * (attn + gates * d * ff)
        total += self.vocab * d * (1 if self.tie_embeddings else 2)
        return int(total)

    # -- reduced config for CPU smoke tests -------------------------------
    def reduced(self) -> "ArchConfig":
        changes = dict(
            n_layers=max(2, self.layer_group),
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, 4 // max(1, self.q_groups)),
            head_dim=16,
            d_ff=128,
            vocab=256,
            n_encoder_layers=2 if self.n_encoder_layers else 0,
            sliding_window=16 if self.sliding_window else 0,
            n_frontend_tokens=4 if self.n_frontend_tokens else 0,
        )
        if self.moe:
            # capacity_factor=n_experts -> cap == S*k: no token drops, so
            # decode matches the full forward exactly in the smoke tests
            changes["moe"] = dataclasses.replace(
                self.moe, n_experts=min(8, self.moe.n_experts),
                top_k=min(2, self.moe.top_k), d_ff_expert=32,
                n_shared_experts=min(1, self.moe.n_shared_experts),
                capacity_factor=float(min(8, self.moe.n_experts)))
        if self.ssm:
            changes["ssm"] = dataclasses.replace(
                self.ssm, head_dim=16, state_dim=4)
        return dataclasses.replace(self, **changes)


# shape cells assigned to every architecture (system prompt)
@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}
