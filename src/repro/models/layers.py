"""Shared neural layers: norms, rotary embeddings, MLP variants, inits."""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from ..sharding.context import use_weight


def normal_init(key, shape, scale: float = 0.02, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ----------------------------------------------------------------- norms
def init_norm(cfg, d: int, dtype=jnp.float32):
    p = {"scale": jnp.ones((d,), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(cfg, p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_heads(x, scale, eps: float = 1e-6):
    """qk-norm: RMSNorm over the head dimension (qwen3)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ------------------------------------------------------------------ rope
def rope_angles(positions, head_dim: int, theta: float):
    """positions: (...,) int -> cos/sin of shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) * 2.0 / head_dim))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., S, H, hd); cos/sin: (..., S, hd//2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(x.dtype)
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ------------------------------------------------------------------- mlp
def init_mlp(key, cfg, d: int, d_ff: int, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    gated = cfg.mlp in ("swiglu", "geglu")
    p = {"w_in": normal_init(ks[0], (d, d_ff), dtype=dtype),
         "w_out": normal_init(ks[1], (d_ff, d), scale=0.02 / np.sqrt(2 * cfg.n_layers),
                              dtype=dtype)}
    if gated:
        p["w_gate"] = normal_init(ks[2], (d, d_ff), dtype=dtype)
    return p


def apply_mlp(cfg, p, x):
    h = x @ use_weight(p["w_in"].astype(x.dtype), (None, "model"))
    if cfg.mlp == "swiglu":
        g = x @ use_weight(p["w_gate"].astype(x.dtype), (None, "model"))
        h = jax.nn.silu(g) * h
    elif cfg.mlp == "geglu":
        g = x @ use_weight(p["w_gate"].astype(x.dtype), (None, "model"))
        h = jax.nn.gelu(g, approximate=True) * h
    elif cfg.mlp == "relu2":                       # nemotron squared-ReLU
        h = jnp.square(jax.nn.relu(h))
    else:                                          # gelu (starcoder2)
        h = jax.nn.gelu(h, approximate=True)
    return h @ use_weight(p["w_out"].astype(x.dtype), ("model", None))
