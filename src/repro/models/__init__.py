"""Model zoo: unified transformer/SSM/MoE/hybrid stack (DESIGN.md §4)."""
from .config import ArchConfig, MoEConfig, SSMConfig, SHAPES, ShapeCell
from .transformer import (cross_entropy_loss, forward_decode, forward_prefill,
                          forward_train, init_cache, init_lm, layer_kinds)

__all__ = ["ArchConfig", "MoEConfig", "SSMConfig", "SHAPES", "ShapeCell",
           "init_lm", "init_cache", "forward_train", "forward_prefill",
           "forward_decode", "cross_entropy_loss", "layer_kinds"]
