"""Grouped-query attention: full/sliding-window causal, cross, and cached
decode.  The blocked-softmax compute path dispatches to the Pallas flash
kernel on TPU (kernels/flash_attention) with a pure-jnp fallback elsewhere.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from ..sharding.context import pin_attention_blocks, shard_heads, use_weight
from .layers import apply_rope, normal_init, rms_norm_heads, rope_angles

NEG_INF = -1e30


def init_attention(key, cfg, dtype=jnp.float32, cross: bool = False):
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 6)
    p = {
        "wq": normal_init(ks[0], (d, cfg.n_heads * hd), dtype=dtype),
        "wk": normal_init(ks[1], (d, cfg.n_kv_heads * hd), dtype=dtype),
        "wv": normal_init(ks[2], (d, cfg.n_kv_heads * hd), dtype=dtype),
        "wo": normal_init(ks[3], (cfg.n_heads * hd, d), dtype=dtype),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _project_qkv(cfg, p, xq, xkv):
    B, Sq, _ = xq.shape
    Skv = xkv.shape[1]
    hd = cfg.hd
    q = (xq @ use_weight(p["wq"].astype(xq.dtype), (None, "model"))
         ).reshape(B, Sq, cfg.n_heads, hd)
    k = (xkv @ use_weight(p["wk"].astype(xq.dtype), (None, "model"))
         ).reshape(B, Skv, cfg.n_kv_heads, hd)
    v = (xkv @ use_weight(p["wv"].astype(xq.dtype), (None, "model"))
         ).reshape(B, Skv, cfg.n_kv_heads, hd)
    if "q_norm" in p:
        q = rms_norm_heads(q, p["q_norm"])
        k = rms_norm_heads(k, p["k_norm"])
    return shard_heads(q), shard_heads(k), shard_heads(v)


def _sdpa(cfg, q, k, v, mask):
    """q: (B,Sq,H,hd), k/v: (B,Skv,Hkv,hd), mask: (Sq,Skv) or (B,1,Sq,Skv)."""
    B, Sq, H, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, Sq, Hkv, g, hd)
    scale = hd ** -0.5
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg * scale, k,
                        preferred_element_type=jnp.float32)
    if cfg.logit_softcap > 0:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    if mask is not None:
        if mask.ndim == 2:
            mask = mask[None, None, None]
        else:
            mask = mask[:, :, None]
        logits = jnp.where(mask, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", w, v)
    return o.reshape(B, Sq, H * hd)


import functools as _functools


def _block_scores(qblk, kblk, qi, kj, q_chunk, kv_chunk, *, causal, window,
                  softcap):
    """Masked (softcapped) score block in f32.  qblk pre-scaled.
    Returns (s, tanh_grad or None)."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk,
                   preferred_element_type=jnp.float32)
    tgrad = None
    if softcap > 0:
        t = jnp.tanh(s / softcap)
        tgrad = 1.0 - t * t
        s = t * softcap
    q_pos = qi * q_chunk + jnp.arange(q_chunk)
    k_pos = kj * kv_chunk + jnp.arange(kv_chunk)
    mask = jnp.ones((q_chunk, kv_chunk), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    return s, tgrad, mask


@_functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _chunked_attention(q, k, v, causal, window, softcap, q_chunk, kv_chunk):
    """Flash attention in pure lax: blocked online softmax with an O(S·d)
    custom VJP that recomputes score blocks (the autodiff'd scan would save
    every (m, l, acc) carry — ~19 GB/layer at 4k x d18432).  This is both
    the XLA fallback for long sequences and the numerical reference for the
    Pallas kernel."""
    out, _ = _chunked_fwd_impl(q, k, v, causal, window, softcap, q_chunk,
                               kv_chunk)
    return out


def _chunked_fwd_impl(q, k, v, causal, window, softcap, q_chunk, kv_chunk):
    B, Sq, H, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    nq, nk = Sq // q_chunk, Skv // kv_chunk
    scale = hd ** -0.5

    qg = jnp.moveaxis((q * scale).reshape(B, nq, q_chunk, Hkv, g, hd), 1, 0)
    kb = jnp.moveaxis(k.reshape(B, nk, kv_chunk, Hkv, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, kv_chunk, Hkv, hd), 1, 0)
    qg, kb, vb = pin_attention_blocks(qg, kb, vb)

    def q_block(_, qi_and_q):
        qi, qblk = qi_and_q

        def kv_block(carry, kj_and_kv):
            m, l, acc = carry
            kj, kblk, vblk = kj_and_kv
            s, _, _ = _block_scores(qblk, kblk, qi, kj, q_chunk, kv_chunk,
                                    causal=causal, window=window,
                                    softcap=softcap)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk)
            acc_new = acc * corr[..., None].astype(acc.dtype) + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Hkv, g, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0),
                                      (jnp.arange(nk), kb, vb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))        # (B,Hkv,g,qc)
        out = jnp.moveaxis(out, 3, 1).reshape(B, q_chunk, H * hd)
        return None, (out, lse)

    _, (outs, lses) = jax.lax.scan(q_block, None, (jnp.arange(nq), qg))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H * hd).astype(q.dtype)
    lse = jnp.moveaxis(lses, 0, 3).reshape(B, Hkv, g, Sq)
    return out, lse


def _chunked_fwd(q, k, v, causal, window, softcap, q_chunk, kv_chunk):
    out, lse = _chunked_fwd_impl(q, k, v, causal, window, softcap, q_chunk,
                                 kv_chunk)
    return out, (q, k, v, out, lse)


def _chunked_bwd(causal, window, softcap, q_chunk, kv_chunk, res, dout):
    q, k, v, out, lse = res
    B, Sq, H, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    nq, nk = Sq // q_chunk, Skv // kv_chunk
    scale = hd ** -0.5

    do = dout.reshape(B, Sq, Hkv, g, hd).astype(jnp.float32)
    og = out.reshape(B, Sq, Hkv, g, hd).astype(jnp.float32)
    # D = rowsum(do * o): (B, Hkv, g, Sq)
    D = jnp.einsum("bqhgd,bqhgd->bhgq", do, og)

    qg = jnp.moveaxis((q * scale).reshape(B, nq, q_chunk, Hkv, g, hd), 1, 0)
    dog = jnp.moveaxis(do.reshape(B, nq, q_chunk, Hkv, g, hd), 1, 0)
    kb = jnp.moveaxis(k.reshape(B, nk, kv_chunk, Hkv, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, kv_chunk, Hkv, hd), 1, 0)
    lse_b = jnp.moveaxis(lse.reshape(B, Hkv, g, nq, q_chunk), 3, 0)
    D_b = jnp.moveaxis(D.reshape(B, Hkv, g, nq, q_chunk), 3, 0)

    def p_and_ds(qblk, kblk, vblk, doblk, lseblk, Dblk, qi, kj):
        s, tgrad, mask = _block_scores(qblk, kblk, qi, kj, q_chunk, kv_chunk,
                                       causal=causal, window=window,
                                       softcap=softcap)
        p = jnp.exp(s - lseblk[..., None])               # (B,h,g,qc,kc)
        dp = jnp.einsum("bqhgd,bkhd->bhgqk", doblk, vblk)
        ds = p * (dp - Dblk[..., None])
        if softcap > 0:
            ds = ds * tgrad
        ds = jnp.where(mask[None, None, None], ds, 0.0)
        return p, ds

    # pass 1: dq, scanning q blocks (inner over kv)
    def dq_block(_, xs):
        qi, qblk, doblk, lseblk, Dblk = xs

        def inner(dq, kv):
            kj, kblk, vblk = kv
            _, ds = p_and_ds(qblk, kblk, vblk, doblk, lseblk, Dblk, qi, kj)
            return dq + jnp.einsum("bhgqk,bkhd->bqhgd", ds,
                                   kblk.astype(jnp.float32)), None

        dq0 = jnp.zeros((B, q_chunk, Hkv, g, hd), jnp.float32)
        dq, _ = jax.lax.scan(inner, dq0, (jnp.arange(nk), kb, vb))
        return None, dq * scale

    _, dqs = jax.lax.scan(dq_block, None,
                          (jnp.arange(nq), qg, dog, lse_b, D_b))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(B, Sq, H, hd).astype(q.dtype)

    # pass 2: dk/dv, scanning kv blocks (inner over q)
    def dkv_block(_, xs):
        kj, kblk, vblk = xs

        def inner(carry, qs):
            dk, dv = carry
            qi, qblk, doblk, lseblk, Dblk = qs
            p, ds = p_and_ds(qblk, kblk, vblk, doblk, lseblk, Dblk, qi, kj)
            dk = dk + jnp.einsum("bhgqk,bqhgd->bkhd", ds, qblk)
            dv = dv + jnp.einsum("bhgqk,bqhgd->bkhd", p, doblk)
            return (dk, dv), None

        z = jnp.zeros((B, kv_chunk, Hkv, hd), jnp.float32)
        (dk, dv), _ = jax.lax.scan(inner, (z, z),
                                   (jnp.arange(nq), qg, dog, lse_b, D_b))
        return None, (dk, dv)

    _, (dks, dvs) = jax.lax.scan(dkv_block, None, (jnp.arange(nk), kb, vb))
    dk = jnp.moveaxis(dks, 0, 1).reshape(B, Skv, Hkv, hd).astype(k.dtype)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(B, Skv, Hkv, hd).astype(v.dtype)
    return dq, dk, dv


_chunked_attention.defvjp(_chunked_fwd, _chunked_bwd)


def _sdpa_chunked(cfg, q, k, v, *, causal: bool = True, window: int = 0,
                  q_chunk: int = 512, kv_chunk: int = 1024):
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    while Sq % q_chunk:
        q_chunk //= 2
    while Skv % kv_chunk:
        kv_chunk //= 2
    return _chunked_attention(q, k, v, causal, window,
                              float(cfg.logit_softcap), q_chunk, kv_chunk)


CHUNKED_THRESHOLD = 2048


def causal_mask(Sq: int, Skv: int, window: int = 0, offset: int = 0):
    """(Sq, Skv) boolean: query i attends key j iff j <= i+offset and, with a
    sliding window, i+offset - j < window."""
    qi = jnp.arange(Sq)[:, None] + offset
    kj = jnp.arange(Skv)[None, :]
    m = kj <= qi
    if window > 0:
        m &= (qi - kj) < window
    return m


def attend_full(cfg, p, x, positions, *, window: int = 0,
                use_flash: bool = False, bidirectional: bool = False):
    """Self-attention over a full sequence (train / prefill).

    Returns (out, (k, v)) so prefill can seed the decode cache.
    """
    q, k, v = _project_qkv(cfg, p, x, x)
    cos, sin = rope_angles(positions, cfg.hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    S = x.shape[1]
    if use_flash and not bidirectional:
        from ..kernels.flash_attention import ops as flash_ops
        o = flash_ops.flash_attention(q, k, v, window=window,
                                      softcap=cfg.logit_softcap)
        o = o.reshape(*o.shape[:2], -1)
    elif S >= CHUNKED_THRESHOLD:
        # long sequences: blocked online-softmax (O(S^2) logits never
        # materialize — required for the 32k prefill cells to fit HBM)
        o = _sdpa_chunked(cfg, q, k, v, causal=not bidirectional,
                          window=window)
    else:
        mask = None if bidirectional else causal_mask(S, S, window)
        o = _sdpa(cfg, q, k, v, mask)
    return o @ use_weight(p["wo"].astype(x.dtype), ("model", None)), (k, v)


def attend_cross(cfg, p, x, kv_src):
    """Cross-attention (enc-dec): no rope, no mask (full source)."""
    q, k, v = _project_qkv(cfg, p, x, kv_src)
    o = _sdpa(cfg, q, k, v, None)
    return o @ use_weight(p["wo"].astype(x.dtype), ("model", None))


def init_kv_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    hd = cfg.hd
    return {"k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
            "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype)}


def attend_decode(cfg, p, x, cache, pos, *, window: int = 0):
    """Single-token decode against a KV cache.

    x: (B, 1, d); cache: dict(k,v) of (B, Smax, Hkv, hd); pos: scalar int —
    the index of the new token (same for the whole batch).
    """
    B = x.shape[0]
    q, k_new, v_new = _project_qkv(cfg, p, x, x)
    posv = jnp.full((B, 1), pos, jnp.int32)
    cos, sin = rope_angles(posv, cfg.hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k_new = apply_rope(k_new, cos, sin)
    Smax = k_new.shape[1] and cache["k"].shape[1]
    ring = window > 0 and Smax <= window     # ring buffer (slot = pos % W)
    slot = pos % Smax if ring else pos
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, slot, 0, 0))
    kj = jnp.arange(Smax)[None, :]
    if ring:
        # every resident slot is within the window by construction; only
        # not-yet-written slots (early decode) are masked out
        m = (kj <= pos) | jnp.full((1, Smax), pos >= Smax)
    else:
        m = kj <= pos                   # (1, Smax) == (Sq=1, Skv)
        if window > 0:
            m &= (pos - kj) < window
    o = _sdpa(cfg, q, k.astype(x.dtype), v.astype(x.dtype), m)
    return o @ use_weight(p["wo"].astype(x.dtype), ("model", None)), {"k": k, "v": v}
