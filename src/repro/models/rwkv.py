"""RWKV6 "Finch" token mixer: token shift + data-dependent per-channel decay
WKV recurrence (arXiv:2404.05892), plus the RWKV channel-mix FFN.

State per head: S ∈ R^{hd × hd}; per step
    y_t = r_t · (S_{t-1} + (u ⊙ k_t) v_tᵀ)
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ
with w_t = exp(-exp(ŵ_t)) data-dependent via a LoRA on the shifted input.

Sequence mode uses ``lax.scan`` (the Pallas kernel in kernels/rwkv6 is the
TPU fast path); decode mode is a single O(1) state update — this is why
rwkv6 runs the long_500k cell.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..sharding.context import use_weight
from .layers import normal_init


def init_rwkv(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    hd = cfg.ssm.head_dim
    H = d // hd
    ks = jax.random.split(key, 12)
    lora = max(32, d // 16)
    return {
        "mix_r": jnp.full((d,), 0.5, dtype), "mix_k": jnp.full((d,), 0.5, dtype),
        "mix_v": jnp.full((d,), 0.5, dtype), "mix_g": jnp.full((d,), 0.5, dtype),
        "mix_w": jnp.full((d,), 0.5, dtype),
        "wr": normal_init(ks[0], (d, d), dtype=dtype),
        "wk": normal_init(ks[1], (d, d), dtype=dtype),
        "wv": normal_init(ks[2], (d, d), dtype=dtype),
        "wg": normal_init(ks[3], (d, d), dtype=dtype),
        "wo": normal_init(ks[4], (d, d), dtype=dtype),
        # data-dependent decay LoRA: d -> lora -> d
        "w_decay_a": normal_init(ks[5], (d, lora), dtype=dtype),
        "w_decay_b": normal_init(ks[6], (lora, d), dtype=dtype),
        "decay_base": jnp.zeros((d,), dtype),
        "bonus_u": normal_init(ks[7], (H, hd), scale=0.1, dtype=dtype),
        "ln_x_scale": jnp.ones((d,), dtype),
    }


def init_rwkv_state(cfg, batch: int, dtype=jnp.float32):
    d = cfg.d_model
    hd = cfg.ssm.head_dim
    H = d // hd
    return {"shift": jnp.zeros((batch, d), dtype),
            "wkv": jnp.zeros((batch, H, hd, hd), jnp.float32)}


def _mix(x, x_prev, m):
    return x * m + x_prev * (1.0 - m)


def _projections(p, x, x_prev, dtype):
    col = lambda w: use_weight(w.astype(dtype), (None, "model"))
    r = _mix(x, x_prev, p["mix_r"].astype(dtype)) @ col(p["wr"])
    k = _mix(x, x_prev, p["mix_k"].astype(dtype)) @ col(p["wk"])
    v = _mix(x, x_prev, p["mix_v"].astype(dtype)) @ col(p["wv"])
    g = _mix(x, x_prev, p["mix_g"].astype(dtype)) @ col(p["wg"])
    xw = _mix(x, x_prev, p["mix_w"].astype(dtype))
    dec = jnp.tanh(xw @ p["w_decay_a"].astype(dtype)) @ p["w_decay_b"].astype(dtype)
    w = jnp.exp(-jnp.exp((p["decay_base"].astype(jnp.float32)
                          + dec.astype(jnp.float32))))
    return r, k, v, g, w


def _group_norm(x, scale, H):
    """LayerNorm per head over hd (RWKV's ln_x)."""
    B = x.shape[0]
    xs = x.reshape(B, H, -1).astype(jnp.float32)
    mu = jnp.mean(xs, -1, keepdims=True)
    var = jnp.var(xs, -1, keepdims=True)
    y = (xs - mu) * jax.lax.rsqrt(var + 64e-5)
    return (y.reshape(B, -1) * scale.astype(jnp.float32)).astype(x.dtype)


def _wkv_step(S, r, k, v, w, u, H, hd):
    """One recurrence step. r,k,v,w: (B, d); S: (B,H,hd,hd) fp32."""
    B = r.shape[0]
    rh = r.reshape(B, H, hd).astype(jnp.float32)
    kh = k.reshape(B, H, hd).astype(jnp.float32)
    vh = v.reshape(B, H, hd).astype(jnp.float32)
    wh = w.reshape(B, H, hd)
    kv = kh[..., :, None] * vh[..., None, :]              # (B,H,hd,hd)
    y = jnp.einsum("bhi,bhij->bhj", rh, S + u[None, :, :, None] * kv)
    S_new = wh[..., :, None] * S + kv
    return S_new, y.reshape(B, H * hd)


def apply_rwkv_seq(cfg, p, x, state) -> Tuple[jnp.ndarray, dict]:
    """x: (B, S, d) sequence mode (train/prefill) via scan over time."""
    B, S, d = x.shape
    hd = cfg.ssm.head_dim
    H = d // hd
    x_prev = jnp.concatenate([state["shift"].astype(x.dtype)[:, None, :],
                          x[:, :-1, :]], axis=1)
    r, k, v, g, w = _projections(p, x, x_prev, x.dtype)
    u = p["bonus_u"].astype(jnp.float32)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp
        S_new, y = _wkv_step(S, r_t, k_t, v_t, w_t, u, H, hd)
        return S_new, y

    # chunked + checkpointed: only chunk-boundary states are saved for the
    # backward pass (otherwise a 4k-step scan would save 4k full WKV states)
    chunk = 256
    while S % chunk:
        chunk //= 2
    n_chunks = S // chunk

    def chunk_body(S0, inp_chunk):
        xs = tuple(jnp.moveaxis(t, 1, 0) for t in inp_chunk)
        S1, ys = jax.lax.scan(step, S0, xs)
        return S1, jnp.moveaxis(ys, 0, 1)

    chunk_body = jax.checkpoint(chunk_body, prevent_cse=False)

    def outer(S0, inp_chunk):
        return chunk_body(S0, inp_chunk)

    rc = r.reshape(B, n_chunks, chunk, d).swapaxes(0, 1)
    kc = k.reshape(B, n_chunks, chunk, d).swapaxes(0, 1)
    vc = v.reshape(B, n_chunks, chunk, d).swapaxes(0, 1)
    wc = w.reshape(B, n_chunks, chunk, d).swapaxes(0, 1)
    S_final, ys = jax.lax.scan(outer, state["wkv"], (rc, kc, vc, wc))
    y = ys.swapaxes(0, 1).reshape(B, S, d).astype(x.dtype)  # (B,S,d)
    y = _group_norm(y.reshape(B * S, d), p["ln_x_scale"], H).reshape(B, S, d)
    y = y * jax.nn.silu(g)
    out = y @ use_weight(p["wo"].astype(x.dtype), ("model", None))
    new_state = {"shift": x[:, -1, :].astype(jnp.float32), "wkv": S_final}
    return out, new_state


def apply_rwkv_step(cfg, p, x, state) -> Tuple[jnp.ndarray, dict]:
    """x: (B, 1, d) decode mode — O(1) per token."""
    B, _, d = x.shape
    hd = cfg.ssm.head_dim
    H = d // hd
    xt = x[:, 0, :]
    r, k, v, g, w = _projections(p, xt, state["shift"].astype(x.dtype), x.dtype)
    u = p["bonus_u"].astype(jnp.float32)
    S_new, y = _wkv_step(state["wkv"], r, k, v, w, u, H, hd)
    y = _group_norm(y, p["ln_x_scale"], H).astype(x.dtype)
    y = y * jax.nn.silu(g)
    out = (y @ use_weight(p["wo"].astype(x.dtype), ("model", None))
           )[:, None, :]
    return out, {"shift": xt.astype(jnp.float32), "wkv": S_new}


# ----------------------------------------------------------------------
# RWKV channel mix (the FFN used by the rwkv6 family)
# ----------------------------------------------------------------------
def init_channel_mix(key, cfg, dtype=jnp.float32):
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {"mix_k": jnp.full((d,), 0.5, dtype),
            "mix_r": jnp.full((d,), 0.5, dtype),
            "wk": normal_init(ks[0], (d, ff), dtype=dtype),
            "wv": normal_init(ks[1], (ff, d), dtype=dtype),
            "wr": normal_init(ks[2], (d, d), dtype=dtype)}


def apply_channel_mix(cfg, p, x, shift_state):
    """x: (B,S,d); shift_state: (B,d) last token of previous chunk."""
    x_prev = jnp.concatenate([shift_state.astype(x.dtype)[:, None, :],
                          x[:, :-1, :]], axis=1)
    xk = _mix(x, x_prev, p["mix_k"].astype(x.dtype))
    xr = _mix(x, x_prev, p["mix_r"].astype(x.dtype))
    k = jnp.square(jax.nn.relu(
        xk @ use_weight(p["wk"].astype(x.dtype), (None, "model"))))
    r = jax.nn.sigmoid(xr @ use_weight(p["wr"].astype(x.dtype), (None, None)))
    return r * (k @ use_weight(p["wv"].astype(x.dtype), ("model", None))), \
        x[:, -1, :]
